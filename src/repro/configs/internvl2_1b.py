"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B backbone
[arXiv:2404.16821; hf].  24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  The InternViT frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings replacing the first 256
positions.  Pure full attention => long_500k skipped.
"""
from ..models.config import Block, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151655,
    stages=((24, (Block("attn"),)),),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    frontend="vision",
    subquadratic=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=224, vocab=512,
        stages=((2, (Block("attn"),)),),
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        frontend="vision",
        dtype="float32",
    )
