"""RALT-in-JAX: the paper's hotness tracker over dense unit ids.

The tracked units on TPU (KV pages, experts, vocab rows) are dense
integers, so RALT's on-disk LSM becomes a fixed-capacity on-device
score table — but the *algorithms* are the paper's, unchanged:

  * exponential-smoothing scores with lazy decay:
    real_score(now) = alpha^(now - tick) * score   (§3.2), updated by
    the fused Pallas kernel `kernels.ops.ralt_update`;
  * time slices advance every `gamma x fast-tier bytes` accessed (§3.2);
  * eviction / hot-threshold via the paper's *sampling* scheme: sample
    positions uniformly in cumulative-size space, take the k-th largest
    sampled score (§3.2 Fig. 4);
  * auto-tuning of the hot-set size limit via Algorithm 1: counters c
    (+delta_c per hit, capped c_max, -1 per R bytes accessed) and
    stability tags t; limit = clamp(stable_size + D_hs, [L_hs, R_hs]).

Everything is jit-compatible (fixed shapes); the host only reads back
scalars (hot set size, limits) for orchestration decisions.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    n_units: int                  # tracked units (pages/experts/rows)
    unit_bytes: int               # HotRAP size of one unit
    fast_bytes: int               # fast-tier capacity in bytes
    alpha: float = 0.999
    gamma: float = 0.001          # time slice per gamma*fast_bytes
    # Algorithm 1
    delta_c: float = 2.6
    c_max: float = 5.0
    hot_lo_frac: float = 0.05     # L_hs / fast_bytes
    hot_hi_frac: float = 0.70     # R_hs
    d_hs_frac: float = 0.10       # D_hs / R_hs
    init_hot_frac: float = 0.50
    n_samples: int = 256          # sampling-based threshold (§3.2)


def init_state(cfg: TrackerConfig) -> dict:
    n = cfg.n_units
    return {
        "tick": jnp.zeros(n, jnp.int32),
        "score": jnp.zeros(n, F32),
        "c": jnp.zeros(n, F32),               # Alg. 1 counter
        "t": jnp.zeros(n, jnp.bool_),         # Alg. 1 stability tag
        "seen": jnp.zeros(n, jnp.bool_),
        "now": jnp.zeros((), jnp.int32),
        "accessed_bytes": jnp.zeros((), F32),     # since last slice
        "accessed_bytes_r": jnp.zeros((), F32),   # since last decrement
        "hot_limit": jnp.asarray(
            cfg.init_hot_frac * cfg.fast_bytes, F32),
        "threshold": jnp.zeros((), F32),
    }


def _slice_every(cfg):
    return cfg.gamma * cfg.fast_bytes


def record_accesses(state, hit_mask, cfg: TrackerConfig):
    """Log one batch of accesses (bool mask over units).  Advances the
    time slice when gamma*fast_bytes have been accessed, applies the
    fused decay+hit kernel, and runs Alg. 1's counter updates."""
    batch_bytes = hit_mask.sum().astype(F32) * cfg.unit_bytes
    acc = state["accessed_bytes"] + batch_bytes
    adv = (acc // _slice_every(cfg)).astype(jnp.int32)
    now = state["now"] + adv
    acc = acc - adv.astype(F32) * _slice_every(cfg)

    new_tick, new_score, _ = kops.ralt_update(
        state["tick"], state["score"], hit_mask, now,
        state["threshold"], alpha=cfg.alpha)

    # Algorithm 1 counters
    c = jnp.where(hit_mask,
                  jnp.minimum(state["c"] + cfg.delta_c, cfg.c_max),
                  state["c"])
    t = jnp.where(hit_mask & state["seen"], True, state["t"])
    seen = state["seen"] | hit_mask

    # decrement sweep every R bytes accessed
    R = cfg.hot_hi_frac * cfg.fast_bytes
    accr = state["accessed_bytes_r"] + batch_bytes
    dec = (accr // R).astype(F32)
    accr = accr - dec * R
    c = jnp.maximum(c - dec, 0.0)
    t = t & (c > 0)

    return {**state, "tick": new_tick, "score": new_score, "c": c,
            "t": t, "seen": seen, "now": now, "accessed_bytes": acc,
            "accessed_bytes_r": accr}


def current_scores(state, cfg: TrackerConfig):
    """Lazily-decayed scores at `now` (§3.2 real_score)."""
    dt = (state["now"] - state["tick"]).astype(F32)
    return state["score"] * jnp.power(jnp.asarray(cfg.alpha, F32), dt)


def sampled_threshold(state, cfg: TrackerConfig, target_bytes):
    """The paper's eviction-threshold sampling (§3.2, Fig. 4).

    Sample n positions uniformly in cumulative-size space (uniform unit
    sizes => uniform unit ids), take the k-th largest sampled score
    where k = n * target_bytes / total_bytes."""
    scores = current_scores(state, cfg)
    n = cfg.n_samples
    key = jax.random.fold_in(jax.random.key(17), state["now"])
    idx = jax.random.randint(key, (n,), 0, cfg.n_units)
    samp = jnp.sort(scores[idx])[::-1]            # descending
    total = cfg.n_units * cfg.unit_bytes
    k = jnp.clip((n * target_bytes / total).astype(jnp.int32),
                 0, n - 1)
    return samp[k]


def update_limits(state, cfg: TrackerConfig):
    """Alg. 1 lines 18–21: hot-set limit from the stable-record size;
    refresh the hot threshold from the sampled quantile."""
    stable = (state["c"] > 0) & state["t"]
    stable_bytes = stable.sum().astype(F32) * cfg.unit_bytes
    L = cfg.hot_lo_frac * cfg.fast_bytes
    Rl = cfg.hot_hi_frac * cfg.fast_bytes
    D = cfg.d_hs_frac * Rl
    hot_limit = jnp.maximum(L, jnp.minimum(stable_bytes + D, Rl))
    threshold = sampled_threshold(state, cfg, hot_limit)
    return {**state, "hot_limit": hot_limit, "threshold": threshold}


def hot_mask(state, cfg: TrackerConfig):
    """Units currently above the hot threshold (bounded by hot_limit
    through the threshold construction)."""
    return current_scores(state, cfg) >= jnp.maximum(state["threshold"],
                                                     1e-6)


class HotTracker:
    """Convenience stateful wrapper (jitted pure ops inside)."""

    def __init__(self, cfg: TrackerConfig):
        self.cfg = cfg
        self.state = init_state(cfg)
        self._build_jits()

    def _build_jits(self):
        cfg = self.cfg
        self._record = jax.jit(
            lambda s, m: record_accesses(s, m, cfg))
        self._limits = jax.jit(lambda s: update_limits(s, cfg))
        self._hot = jax.jit(lambda s: hot_mask(s, cfg))

    def __getstate__(self):
        """Jitted closures don't pickle; rebuild them on load."""
        state = dict(self.__dict__)
        for k in ("_record", "_limits", "_hot"):
            state.pop(k, None)
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        self._build_jits()

    def record(self, hit_mask):
        self.state = self._record(self.state, hit_mask)

    def record_ids(self, ids):
        mask = jnp.zeros(self.cfg.n_units, bool).at[ids].set(True)
        self.record(mask)

    def refresh_limits(self):
        self.state = self._limits(self.state)

    def hot(self):
        return self._hot(self.state)

    def scores(self):
        return current_scores(self.state, self.cfg)
