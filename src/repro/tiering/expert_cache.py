"""Tiered MoE expert weights: hot experts resident in HBM.

qwen3-moe has 128 experts x ~29 MiB (bf16, d=4096, ff=1536, 3 mats)
per layer — 3.6 GiB/layer, 347 GiB total: far beyond HBM at small
serving footprints, with Zipf-skewed routing in production traces.
The RALT tracker scores experts by routed-token counts; swaps follow
the paper's pathways (retention of hot residents during eviction,
batch promotion of hot non-residents).  Unlike KV pages, expert
weights are immutable during serving => no version hazard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.serving import NULL_SERVING_OBS
from .hotness import HotTracker, TrackerConfig
from .kvcache import HBM_BW, PCIE_BW, SimClock


class ExpertCache:
    # Compiled-out-by-default obs plane (see repro.obs.serving).
    _obs = NULL_SERVING_OBS
    _obs_track = "expert"

    def __init__(self, expert_weights: np.ndarray, fast_experts: int,
                 swap_every: int = 16):
        """expert_weights: host array (E, ...) — one blob per expert."""
        self.host = expert_weights
        E = expert_weights.shape[0]
        self.E = E
        self.fast_experts = fast_experts
        self.blob_bytes = int(expert_weights[0].nbytes)
        self.cache = jnp.zeros((fast_experts, *expert_weights.shape[1:]),
                               expert_weights.dtype)
        self.slot_of = np.full(E, -1, np.int64)
        self.expert_of_slot = np.full(fast_experts, -1, np.int64)
        self.free = list(range(fast_experts))[::-1]
        self.tracker = HotTracker(TrackerConfig(
            n_units=E, unit_bytes=self.blob_bytes,
            fast_bytes=fast_experts * self.blob_bytes))
        self.clock = SimClock()
        self.swap_every = swap_every
        self._steps = 0

    def route(self, expert_counts: np.ndarray):
        """Record one step's router histogram (E,) and fetch weights.
        Resident experts are HBM reads; non-resident experts are
        streamed from host (PCIe) for this step and staged."""
        obs, c = self._obs, self.clock
        if obs.enabled:
            t0 = c.total_s
            s0, m0 = c.slow_hits, c.sweeps
        used = np.nonzero(expert_counts > 0)[0]
        hits = jnp.zeros(self.E, bool).at[jnp.asarray(used)].set(True)
        self.tracker.record(hits)
        for e in used:
            if self.slot_of[e] >= 0:
                self.clock.hbm_s += self.blob_bytes / HBM_BW
                self.clock.fast_hits += 1
            else:
                self.clock.pcie_s += self.blob_bytes / PCIE_BW
                self.clock.slow_hits += 1
        self._steps += 1
        if self._steps % self.swap_every == 0:
            self.rebalance()
        if obs.enabled:
            if obs.attribution:
                obs.attr.observe("expert", c.total_s - t0, len(used),
                                 c.slow_hits - s0, c.sweeps > m0)
            obs.on_access()

    def rebalance(self):
        """Sweep: retain hot residents, demote cold ones, promote the
        hottest non-residents into freed slots."""
        obs, c = self._obs, self.clock
        if obs.enabled:
            obs.tracer.begin(
                self._obs_track, "expert/rebalance",
                {"resident": int((self.expert_of_slot >= 0).sum())})
            r0, d0, p0 = c.retained, c.demoted, c.promoted
        self.tracker.refresh_limits()
        scores = np.asarray(self.tracker.scores())
        hot = np.asarray(self.tracker.hot())
        order = np.argsort(-scores)
        want = [int(e) for e in order[:self.fast_experts] if hot[e]]
        want_set = set(want)
        for s, e in enumerate(self.expert_of_slot):
            if e >= 0 and e not in want_set:
                self.slot_of[e] = -1
                self.expert_of_slot[s] = -1
                self.free.append(int(s))
                self.clock.demoted += 1
            elif e >= 0:
                self.clock.retained += 1
        new = [e for e in want if self.slot_of[e] < 0]
        slots = []
        for e in new:
            if not self.free:
                break
            s = self.free.pop()
            slots.append(s)
            self.slot_of[e] = s
            self.expert_of_slot[s] = e
        if slots:
            self.cache = self.cache.at[jnp.asarray(slots)].set(
                jnp.asarray(self.host[new[:len(slots)]]))
            self.clock.pcie_s += len(slots) * self.blob_bytes / PCIE_BW
            self.clock.promoted += len(slots)
        c.sweeps += 1
        if obs.enabled:
            tr, track = obs.tracer, self._obs_track
            if c.retained > r0:                       # retention pathway
                tr.instant(track, "page/retained",
                           {"pages": c.retained - r0})
            if c.promoted > p0:                       # promo-by-compaction
                tr.instant(track, "page/promo_compaction",
                           {"pages": c.promoted - p0})
            tr.end(track, "expert/rebalance",
                   {"demoted": c.demoted - d0,
                    "promoted": c.promoted - p0})

    def __getstate__(self):
        state = dict(self.__dict__)
        state.pop("_obs", None)
        state.pop("_obs_track", None)
        return state

    def resident_fraction(self, expert_counts: np.ndarray) -> float:
        """Fraction of routed tokens whose expert is HBM-resident."""
        total = expert_counts.sum()
        if total == 0:
            return 0.0
        res = sum(int(c) for e, c in enumerate(expert_counts)
                  if self.slot_of[e] >= 0)
        return res / float(total)
