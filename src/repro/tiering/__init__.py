from .hotness import HotTracker, TrackerConfig     # noqa: F401
from .kvcache import TieredKVCache, KVTierConfig   # noqa: F401
from .embedding import TieredEmbedding             # noqa: F401
from .expert_cache import ExpertCache              # noqa: F401
