"""Tiered embedding table: hot vocab rows resident in HBM.

The 128k–262k-vocab archs (llama3, minitron, gemma3, qwen3) have
multi-GiB embedding tables with Zipf-skewed row access — exactly the
paper's workload shape.  The full table lives in host memory (SD); a
fixed-size HBM row cache (FD) holds the hot rows, tracked by the RALT
tracker; misses are served from host (PCIe-charged) and staged; staged
rows are bulk-promoted when hot (promotion by flush — embedding rows
are read-only during serving, so the version checks of the KV path are
unnecessary; training updates invalidate via `invalidate_rows`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.serving import NULL_SERVING_OBS
from .hotness import HotTracker, TrackerConfig
from .kvcache import HBM_BW, PCIE_BW, SimClock


class TieredEmbedding:
    # Compiled-out-by-default obs plane (see repro.obs.serving).
    _obs = NULL_SERVING_OBS
    _obs_track = "emb"

    def __init__(self, table: np.ndarray, fast_rows: int,
                 staging_slots: int = 256):
        self.table = table                       # host (V, d)
        V, d = table.shape
        self.fast_rows = fast_rows
        self.cache = jnp.zeros((fast_rows, d), table.dtype)
        self.row_of_slot = np.full(fast_rows, -1, np.int64)
        self.slot_of_row = np.full(V, -1, np.int64)
        self.free = list(range(fast_rows))[::-1]
        self.staging: set[int] = set()
        self.staging_slots = staging_slots
        self.row_bytes = d * table.dtype.itemsize
        self.tracker = HotTracker(TrackerConfig(
            n_units=V, unit_bytes=self.row_bytes,
            fast_bytes=fast_rows * self.row_bytes))
        self.clock = SimClock()

    def lookup(self, token_ids) -> jnp.ndarray:
        """Exact gather (resident rows from HBM, misses from host)."""
        obs = self._obs
        if obs.enabled:
            t0 = self.clock.total_s
            f0 = self.clock.flushes
        ids = np.asarray(token_ids).reshape(-1)
        slots = self.slot_of_row[ids]
        hit = slots >= 0
        out = np.empty((len(ids), self.table.shape[1]), self.table.dtype)
        if hit.any():
            got = jnp.take(self.cache, jnp.asarray(slots[hit]), axis=0)
            out[hit] = np.asarray(got)
            uniq = len(np.unique(ids[hit]))
            self.clock.hbm_s += uniq * self.row_bytes / HBM_BW
            self.clock.fast_hits += int(hit.sum())
        miss = ~hit
        if miss.any():
            rows = np.unique(ids[miss])
            out[miss] = self.table[ids[miss]]
            self.clock.pcie_s += len(rows) * self.row_bytes / PCIE_BW
            self.clock.slow_hits += int(miss.sum())
            self.staging.update(int(r) for r in rows)
        self.tracker.record_ids(jnp.asarray(np.unique(ids), jnp.int32))
        if len(self.staging) >= self.staging_slots:
            self.flush_promote()
        if obs.enabled:
            if obs.attribution:
                obs.attr.observe(
                    "emb", self.clock.total_s - t0, len(ids),
                    int(miss.sum()), self.clock.flushes > f0)
            obs.on_access()
        return jnp.asarray(out).reshape(*np.shape(token_ids), -1)

    def flush_promote(self):
        """Promotion by flush: hot staged rows -> HBM cache; cold
        resident rows are evicted to make room (retention keeps hot)."""
        obs, c = self._obs, self.clock
        if obs.enabled:
            obs.tracer.begin(self._obs_track, "emb/flush_promote",
                             {"staged": len(self.staging)})
            r0, p0 = c.retained, c.promoted
        self.tracker.refresh_limits()
        hot = np.asarray(self.tracker.hot())
        scores = np.asarray(self.tracker.scores())
        want = [r for r in self.staging if hot[r]]
        self.staging.clear()
        c.flushes += 1
        if not want:
            if obs.enabled:
                obs.tracer.end(self._obs_track, "emb/flush_promote",
                               {"promoted": 0})
            return
        # evict coldest residents if needed
        if len(self.free) < len(want):
            resident = [r for r in self.row_of_slot if r >= 0]
            resident.sort(key=lambda r: scores[r])
            for r in resident[:len(want) - len(self.free)]:
                if hot[r]:
                    self.clock.retained += 1    # retention: keep hot
                    continue
                s = self.slot_of_row[r]
                self.slot_of_row[r] = -1
                self.row_of_slot[s] = -1
                self.free.append(int(s))
                self.clock.demoted += 1
        new_slots, new_rows = [], []
        for r in want:
            if not self.free:
                break
            s = self.free.pop()
            new_slots.append(s)
            new_rows.append(r)
            self.slot_of_row[r] = s
            self.row_of_slot[s] = r
        if new_rows:
            self.cache = self.cache.at[jnp.asarray(new_slots)].set(
                jnp.asarray(self.table[new_rows]))
            self.clock.pcie_s += (len(new_rows) * self.row_bytes
                                  / PCIE_BW)
            self.clock.promoted += len(new_rows)
        if obs.enabled:
            tr, track = obs.tracer, self._obs_track
            if c.retained > r0:                       # retention pathway
                tr.instant(track, "page/retained",
                           {"pages": c.retained - r0})
            if c.promoted > p0:                       # promo-by-flush
                tr.instant(track, "page/promo_flush",
                           {"pages": c.promoted - p0})
            tr.end(track, "emb/flush_promote",
                   {"promoted": c.promoted - p0})

    def __getstate__(self):
        state = dict(self.__dict__)
        state.pop("_obs", None)
        state.pop("_obs_track", None)
        return state

    def invalidate_rows(self, rows):
        for r in np.asarray(rows).reshape(-1):
            s = self.slot_of_row[r]
            if s >= 0:
                self.slot_of_row[r] = -1
                self.row_of_slot[s] = -1
                self.free.append(int(s))

    def fast_hit_rate(self):
        t = self.clock.fast_hits + self.clock.slow_hits
        return self.clock.fast_hits / t if t else 0.0
