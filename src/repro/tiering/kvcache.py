"""Tiered paged KV cache — the paper's retention/promotion pathways on
the TPU memory hierarchy (HBM = FD, host DRAM = SD).

Pages (fixed tokens/page) live in either the HBM pool or the host pool;
a page table maps logical page -> (tier, slot).  The three pathways
(HotRAP §3.1) map as:

  * retention            — eviction sweeps (the FD->SD "compaction"
    analogue, run when the HBM pool is full) *skip hot pages*: only
    cold pages are demoted to host slots.
  * promotion by compaction — the same sweep checks the staging list of
    recently-accessed host pages in its range and copies the hot ones
    into freed HBM slots.
  * promotion by flush   — when the staging list reaches its capacity
    between sweeps (read-heavy phases with no evictions), hot staged
    pages are bulk-promoted immediately.

Correctness (paper §3.3/3.4 analogue): every page carries a version;
promotion records the version at stage time and aborts if the page was
appended/overwritten since (the "newer version shielded by a stale
promote" hazard).  The abort path is exercised in tests.

The device-side data plane (gathers, copies) is jax; the control plane
(page table, sweeps) is host Python — same split as an LSM-tree's
I/O vs. manifest logic.  `SimClock` charges HBM/PCIe time so benchmarks
report the paper-style simulated throughput on CPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.serving import NULL_SERVING_OBS
from .hotness import HotTracker, TrackerConfig

HBM_BW = 819e9      # v5e bytes/s
PCIE_BW = 16e9      # host<->device bytes/s (slow tier)


@dataclasses.dataclass(frozen=True)
class KVTierConfig:
    n_pages: int                 # logical pages
    fast_slots: int              # HBM pool capacity (pages)
    page_tokens: int = 16
    kv_heads: int = 8
    head_dim: int = 128
    n_layers: int = 1            # pages are per-layer-group blobs
    dtype: str = "bfloat16"
    staging_slots: int = 32      # promotion-by-flush trigger size
    sweep_every: int = 64        # accesses between eviction sweeps

    @property
    def page_bytes(self) -> int:
        return (2 * self.n_layers * self.page_tokens * self.kv_heads
                * self.head_dim * np.dtype(self.dtype).itemsize)


class SimClock:
    def __init__(self):
        self.hbm_s = 0.0
        self.pcie_s = 0.0
        self.fast_hits = 0
        self.slow_hits = 0
        self.promoted = 0
        self.demoted = 0
        self.retained = 0
        self.aborted = 0
        self.sweeps = 0         # maintenance passes (sweep/rebalance)
        self.flushes = 0        # bulk staging flushes

    @property
    def total_s(self):
        return self.hbm_s + self.pcie_s


class TieredKVCache:
    TIER_FAST, TIER_SLOW = 0, 1

    # Observability (repro.obs.serving) is compiled out by default:
    # class-level null plane, one attribute check per site.
    _obs = NULL_SERVING_OBS
    _obs_track = "kv"

    def __init__(self, cfg: KVTierConfig, tracker_cfg: TrackerConfig
                 | None = None, seed: int = 0):
        self.cfg = cfg
        dt = jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, cfg.page_tokens, cfg.kv_heads,
                 cfg.head_dim)
        self.fast_pool = jnp.zeros((cfg.fast_slots, 2, *shape), dt)
        # host pool: numpy (the "SD" tier)
        self.slow_pool = np.zeros((cfg.n_pages, 2, *shape),
                                  np.dtype(cfg.dtype))
        # page table (host): tier, slot, version
        self.tier = np.full(cfg.n_pages, self.TIER_SLOW, np.int8)
        self.slot_of = np.full(cfg.n_pages, -1, np.int64)
        self.version = np.zeros(cfg.n_pages, np.int64)
        self.free_slots = list(range(cfg.fast_slots))[::-1]
        self.page_of_slot = np.full(cfg.fast_slots, -1, np.int64)
        self.staging: dict[int, int] = {}     # page -> staged version
        self.tracker = HotTracker(tracker_cfg or TrackerConfig(
            n_units=cfg.n_pages, unit_bytes=cfg.page_bytes,
            fast_bytes=cfg.fast_slots * cfg.page_bytes))
        self.clock = SimClock()
        self._access_count = 0

    # ------------------------------------------------------------------
    # data plane
    # ------------------------------------------------------------------
    def write_page(self, page: int, k, v):
        """Append/overwrite a page (prefill writes; bumps version)."""
        self.version[page] += 1
        data = np.stack([np.asarray(k), np.asarray(v)])
        if self.tier[page] == self.TIER_FAST:
            s = self.slot_of[page]
            self.fast_pool = self.fast_pool.at[s].set(
                jnp.asarray(data, self.fast_pool.dtype))
            self.clock.hbm_s += self.cfg.page_bytes / HBM_BW
        else:
            self.slow_pool[page] = data
            self.clock.pcie_s += self.cfg.page_bytes / PCIE_BW

    def read_pages(self, pages):
        """Gather pages for attention.  Fast pages: one device gather;
        slow pages: host fetch (PCIe-charged) + staged for promotion."""
        obs = self._obs
        if obs.enabled:
            t0 = self.clock.total_s
            m0 = self.clock.sweeps + self.clock.flushes
        pages = list(int(p) for p in pages)
        out = {}
        fast = [p for p in pages if self.tier[p] == self.TIER_FAST]
        slow = [p for p in pages if self.tier[p] == self.TIER_SLOW]
        if fast:
            slots = jnp.asarray([self.slot_of[p] for p in fast])
            gathered = jnp.take(self.fast_pool, slots, axis=0)
            for i, p in enumerate(fast):
                out[p] = gathered[i]
            self.clock.hbm_s += len(fast) * self.cfg.page_bytes / HBM_BW
            self.clock.fast_hits += len(fast)
        for p in slow:
            out[p] = jnp.asarray(self.slow_pool[p])
            self.clock.pcie_s += self.cfg.page_bytes / PCIE_BW
            self.clock.slow_hits += 1
            # insert into the staging list (the mPC analogue) with the
            # version observed at read time (§3.3 check)
            self.staging.setdefault(p, int(self.version[p]))
        self._record(pages)
        self._maybe_flush()
        self._access_count += 1
        if self._access_count % self.cfg.sweep_every == 0:
            self.sweep()
        if obs.enabled:
            if obs.attribution:
                obs.attr.observe(
                    "kv", self.clock.total_s - t0, len(pages), len(slow),
                    self.clock.sweeps + self.clock.flushes > m0)
            obs.on_access()
        return [out[p] for p in pages]

    # ------------------------------------------------------------------
    # hotness plumbing
    # ------------------------------------------------------------------
    def _record(self, pages):
        self.tracker.record_ids(jnp.asarray(pages, jnp.int32))

    def _hot_set(self):
        self.tracker.refresh_limits()
        return np.asarray(self.tracker.hot())

    # ------------------------------------------------------------------
    # pathways
    # ------------------------------------------------------------------
    def _promote(self, page: int, staged_version: int, hot: bool):
        """Copy page host->HBM if hot, version unchanged, space found,
        and the hot-set size limit (Alg. 1 auto-tuned) has headroom —
        under uniform access the limit collapses to L_hs and promotion
        traffic goes to ~zero (the paper's <1% uniform overhead)."""
        if not hot:
            self.staging.pop(page, None)
            return False
        if self.version[page] != staged_version:      # §3.3/3.4 hazard
            self.clock.aborted += 1
            self.staging.pop(page, None)
            if self._obs.enabled:
                self._obs.tracer.instant(
                    self._obs_track, "page/promo_abort",
                    {"page": int(page),
                     "staged_version": int(staged_version),
                     "version": int(self.version[page])})
            return False
        occupied = self.cfg.fast_slots - len(self.free_slots)
        hot_limit = float(self.tracker.state["hot_limit"])
        if (occupied + 1) * self.cfg.page_bytes > hot_limit:
            return False                              # hot-set cap
        if not self.free_slots:
            return False                              # retry next sweep
        s = self.free_slots.pop()
        self.fast_pool = self.fast_pool.at[s].set(
            jnp.asarray(self.slow_pool[page], self.fast_pool.dtype))
        self.tier[page] = self.TIER_FAST
        self.slot_of[page] = s
        self.page_of_slot[s] = page
        self.clock.pcie_s += self.cfg.page_bytes / PCIE_BW
        self.clock.promoted += 1
        self.staging.pop(page, None)
        return True

    def _demote(self, page: int):
        s = self.slot_of[page]
        self.slow_pool[page] = np.asarray(self.fast_pool[s])
        self.tier[page] = self.TIER_SLOW
        self.slot_of[page] = -1
        self.page_of_slot[s] = -1
        self.free_slots.append(int(s))
        self.clock.pcie_s += self.cfg.page_bytes / PCIE_BW
        self.clock.demoted += 1

    def sweep(self):
        """Scheduled maintenance (the compaction analogue): demote cold
        resident pages (retention skips hot ones), then promote hot
        staged pages into the freed slots (promotion by compaction)."""
        obs, c = self._obs, self.clock
        if obs.enabled:
            obs.tracer.begin(
                self._obs_track, "kv/sweep",
                {"resident": int((self.page_of_slot >= 0).sum()),
                 "staged": len(self.staging)})
            r0, d0, p0, a0 = c.retained, c.demoted, c.promoted, c.aborted
        hot = self._hot_set()
        resident = [int(p) for p in self.page_of_slot if p >= 0]
        for p in resident:
            if hot[p]:
                self.clock.retained += 1              # retention
            elif len(self.free_slots) < max(self.cfg.fast_slots // 4, 1):
                self._demote(p)
        for p, ver in list(self.staging.items()):
            self._promote(p, ver, bool(hot[p]))
        c.sweeps += 1
        if obs.enabled:
            tr, track = obs.tracer, self._obs_track
            if c.retained > r0:                       # retention pathway
                tr.instant(track, "page/retained",
                           {"pages": c.retained - r0})
            if c.promoted > p0:                       # promo-by-compaction
                tr.instant(track, "page/promo_compaction",
                           {"pages": c.promoted - p0})
            tr.end(track, "kv/sweep",
                   {"demoted": c.demoted - d0, "promoted": c.promoted - p0,
                    "aborted": c.aborted - a0})

    def _maybe_flush(self):
        """Promotion by flush: staging full between sweeps."""
        if len(self.staging) < self.cfg.staging_slots:
            return
        obs, c = self._obs, self.clock
        if obs.enabled:
            obs.tracer.begin(self._obs_track, "kv/staging_flush",
                             {"staged": len(self.staging)})
            p0, a0 = c.promoted, c.aborted
        hot = self._hot_set()
        for p, ver in list(self.staging.items()):
            self._promote(p, ver, bool(hot[p]))
        # cold staged pages are dropped (paper: cold immPC records)
        self.staging.clear()
        c.flushes += 1
        if obs.enabled:
            if c.promoted > p0:                       # promo-by-flush
                obs.tracer.instant(self._obs_track, "page/promo_flush",
                                   {"pages": c.promoted - p0})
            obs.tracer.end(self._obs_track, "kv/staging_flush",
                           {"promoted": c.promoted - p0,
                            "aborted": c.aborted - a0})

    # ------------------------------------------------------------------
    def __getstate__(self):
        """Pickle without the obs plane (it holds closures/clock refs);
        the class-level NULL plane reasserts itself on load."""
        state = dict(self.__dict__)
        state.pop("_obs", None)
        state.pop("_obs_track", None)
        return state

    # ------------------------------------------------------------------
    def fast_hit_rate(self):
        t = self.clock.fast_hits + self.clock.slow_hits
        return self.clock.fast_hits / t if t else 0.0
